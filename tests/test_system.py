"""End-to-end behaviour tests for the paper's system."""
import json
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

REPO = Path(__file__).resolve().parents[1]


def test_rope_hoisting_via_race():
    """DESIGN.md section 4: RACE detects that per-layer RoPE trig is
    layer-loop-invariant (empty exprDelta on the layer axis) and hoists it to
    one auxiliary array = the rope cache the models consume."""
    from repro.core.integration import rope_hoisting_plan

    rep = rope_hoisting_plan(n_layers=6, seq=8, half_dh=4)
    assert rep.layer_invariant
    # per-(l,p,d) trig cost collapses by exactly 1/L
    assert rep.sincos_per_iter_after == pytest.approx(
        rep.sincos_per_iter_before / 6, rel=1e-6)
    # hoisted aux arrays live on (p, d) only — no layer dimension
    for aux in rep.result.plan.aux_order:
        assert 1 not in aux.levels  # level 1 = the layer loop


def test_end_to_end_training_loss_decreases(tmp_path):
    """Tiny end-to-end run through the full stack: data pipeline -> sharded
    model -> AdamW -> checkpointing trainer; loss must drop."""
    import dataclasses

    from repro.configs import get_config
    from repro.data import DataConfig, ShardedTokenPipeline
    from repro.models import ExecConfig, init_params, make_train_step
    from repro.optim import AdamWConfig
    from repro.optim.adamw import adamw_init
    from repro.runtime import Trainer, TrainerConfig

    cfg = dataclasses.replace(
        get_config("qwen3_14b").reduced(), vocab=64, d_model=64, num_layers=2)
    ec = ExecConfig(attn_chunk_q=8, attn_chunk_k=8, loss_chunk=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt_cfg, ec, total_steps=30, warmup=3))
    # a tiny repetitive corpus the model can actually learn
    pipe = ShardedTokenPipeline(DataConfig(seq_len=32, global_batch=4,
                                           vocab=cfg.vocab, seed=1))

    class FixedPipe:
        def batch_at(self, step):
            return pipe.batch_at(step % 2)  # near-stationary distribution

    tc = TrainerConfig(total_steps=30, ckpt_dir=str(tmp_path), ckpt_every=100,
                       async_save=False, log_fn=lambda *a: None)
    out = Trainer(tc, step, FixedPipe(), params, adamw_init(params, opt_cfg)).run()
    assert out["losses"][-1] < out["losses"][0]


def test_dryrun_artifacts_complete_and_green():
    """Gate on the committed dry-run sweep: every runnable (arch x shape x
    mesh) cell compiled; skips carry documented reasons (assignment e)."""
    d = REPO / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated in this checkout")
    recs = [json.loads(p.read_text()) for p in d.glob("*.json")
            if len(p.name.split(".")) == 4]  # arch.shape.mesh.json only
    assert len(recs) >= 70  # 40 cells x 2 meshes minus overlap
    bad = [r for r in recs if r["runnable"] and not r.get("ok")]
    assert not bad, [(r["arch"], r["shape"], r["mesh"], r.get("error"))
                     for r in bad]
    skips = [r for r in recs if not r["runnable"]]
    assert all(r["skip_reason"] for r in skips)
    # both meshes covered
    assert {r["mesh"] for r in recs} >= {"pod", "multipod"}


def test_quantized_kv_decode_close_to_exact():
    """int8 KV cache (section Perf, cell C) keeps decode logits close to the
    bf16-cache decode."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import ExecConfig, init_caches, init_params, make_decode_step

    cfg = dataclasses.replace(get_config("granite_3_8b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    ec = ExecConfig(attn_chunk_q=8, attn_chunk_k=8)
    step = jax.jit(make_decode_step(cfg, ec, 16))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (2, 8))
    outs = {}
    for quant in (False, True):
        caches = init_caches(cfg, 2, 16, kv_quant=quant)
        for t in range(8):
            logits, caches = step(params, caches,
                                  jnp.asarray(toks[:, t:t + 1], jnp.int32),
                                  jnp.int32(t))
        outs[quant] = np.asarray(logits)
    # int8 cache: small relative error, identical top-1 predictions
    np.testing.assert_allclose(outs[True], outs[False], rtol=0.1, atol=0.05)
    assert (outs[True].argmax(-1) == outs[False].argmax(-1)).all()


def test_esr_plus_vs_race_across_paper_kernels():
    """Paper section 9.3: RACE beats or ties ESR+ on every case (static op
    totals stand in for runtime on this container)."""
    import sys

    sys.path.insert(0, str(REPO))
    from benchmarks.common import variants

    from repro.apps.paper_kernels import get_case

    for name in ["calc_tpoints", "hdifft_gm", "psinv", "gaussian", "poisson"]:
        v = variants(get_case(name))  # RACE with profit-driven level choice
        assert (v["RACE"].op_table()["weighted_total"]
                <= v["ESR+"].op_table()["weighted_total"] + 1e-9), name
