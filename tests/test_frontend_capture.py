"""Frontend capture: plain-Python loop nests become RACE IR.

Acceptance (ISSUE 2): ``capture()`` reproduces the hand-built ``Program``
— identical plan and ``reduced_ops`` — for the twinned registry cases, and
the captured path flows through the differential harness and the backend
layer like any curated program.
"""
import numpy as np
import pytest

from repro.apps.frontend_kernels import TWINS, as_frontend
from repro.apps.paper_kernels import get_case
from repro.core.codegen import FUNCS, required_shapes
from repro.core.ir import Node, Ref, SourceLoc
from repro.core.race import race, race_from_fn
from repro.frontend import KNOWN_CALLS, RaceKernel, capture, race_kernel
from repro.testing import build_env, coverage_matrix, run_case, sweep_registry
from repro.testing.differential import SWEEP_SIZES

# --------------------------------------------------------------------------
# registry twins: exact reproduction of the curated entry path
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TWINS))
def test_twin_reproduces_handbuilt_program(name):
    case = get_case(name, SWEEP_SIZES.get(name))
    fe = as_frontend(case)  # check=True: raises on any divergence
    assert fe.program == case.program  # same loops, same expression trees

    rh = race(case.program, reassociate=case.reassociate,
              rewrite_div=case.rewrite_div)
    rf = race(fe.program, reassociate=case.reassociate,
              rewrite_div=case.rewrite_div)
    assert rf.to_source() == rh.to_source()  # identical plan
    assert rf.reduced_ops() == rh.reduced_ops()
    assert rf.n_aux() == rh.n_aux()


def test_captured_programs_carry_source_locations():
    case = get_case("psinv", 10)
    fe = as_frontend(case)
    assert isinstance(fe.program.loc, SourceLoc)
    assert fe.program.loc.file.endswith("frontend_kernels.py")
    for st in fe.program.body:
        assert isinstance(st.loc, SourceLoc)
        assert st.loc.line > fe.program.loc.line
    # metadata is advisory: it never participates in program equality
    assert fe.program == case.program and case.program.loc is None


@pytest.mark.parametrize("name", ["calc_tpoints", "j3d27pt"])
def test_frontend_case_through_differential_harness(name):
    case = get_case(name, SWEEP_SIZES.get(name), via="frontend")
    report = run_case(case, reassociate_levels=(case.reassociate,))
    assert not report.failures(), coverage_matrix([report])
    assert report.pallas_covered(), coverage_matrix([report])


def test_sweep_registry_via_frontend_selects_twinned_subset():
    reports = sweep_registry(via="frontend", names=["hdifft_gm"],
                             reassociate_levels=(0,))
    assert [r.case for r in reports] == ["hdifft_gm"]
    assert not [f for r in reports for f in r.failures()]


def test_get_case_rejects_unknown_via_and_missing_twin():
    with pytest.raises(ValueError, match="unknown via"):
        get_case("psinv", 10, via="tracing")
    with pytest.raises(KeyError, match="no plain-Python twin"):
        get_case("derivative", 10, via="frontend")


# --------------------------------------------------------------------------
# the decorator / convenience surface
# --------------------------------------------------------------------------


def _blur(u, out):
    n, m = u.shape
    for i in range(1, n - 1):
        for j in range(1, m - 1):
            out[i, j] = (u[i - 1, j] + u[i + 1, j]) / 2.0


def test_race_from_fn_runs_on_backend_layer():
    shapes = {"u": (10, 8), "out": (10, 8)}
    res = race_from_fn(_blur, shapes, reassociate=0)
    env = {"u": np.random.default_rng(0).uniform(-1, 1, (10, 8))
           .astype(np.float32), "out": np.zeros((10, 8), np.float32)}
    got = res.run(env, backend="auto")
    want = (env["u"][:-2, 1:-1] + env["u"][2:, 1:-1]) / 2
    np.testing.assert_allclose(np.asarray(got["out"]), want, rtol=1e-6)


def test_race_kernel_decorator_caches_and_runs():
    kern = race_kernel(reassociate=0)(_blur)
    assert isinstance(kern, RaceKernel)
    assert kern.__name__ == "_blur"  # functools.update_wrapper applied

    env = {"u": np.random.default_rng(1).uniform(-1, 1, (12, 9))
           .astype(np.float32), "out": np.zeros((12, 9), np.float32)}
    got = kern.run(env)
    want = (env["u"][:-2, 1:-1] + env["u"][2:, 1:-1]) / 2
    np.testing.assert_allclose(np.asarray(got["out"]), want, rtol=1e-6)

    shapes = {k: np.shape(v) for k, v in env.items()}
    assert kern.trace(shapes) is kern.trace(shapes)  # cached RaceResult
    assert kern.capture(shapes) is kern.capture(shapes)
    assert kern.last_capture_seconds is not None
    with pytest.raises(ValueError, match="needs inputs"):
        kern.run({"u": env["u"]})


def test_race_kernel_on_registry_twin_matches_dsl_result():
    case = get_case("calc_tpoints", SWEEP_SIZES["calc_tpoints"])
    kern = race_kernel(TWINS["calc_tpoints"], reassociate=case.reassociate)
    env = build_env(case, np.float32)
    got = kern.run(env, backend="xla")
    res = race(case.program, reassociate=case.reassociate)
    want = res.run(env, "xla")
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-6)


def test_capture_consts_parameterize_bounds():
    def roll(u, out, n):
        for i in range(1, n):
            out[i] = u[i] + u[i - 1]

    prog = capture(roll, {"u": (16,), "out": (16,)}, consts={"n": 16})
    assert prog.loops[0].hi == 15
    prog8 = capture(roll, {"u": (16,), "out": (16,)}, consts={"n": 8})
    assert prog8.loops[0].hi == 7


def test_capture_negative_and_strided_subscripts():
    def mixed(u, out):
        n, m = u.shape
        for i in range(1, 5):
            for j in range(0, 4):
                out[i, j] = u[2 * i + 1, j] + u[8 - i, 3 * j]

    prog = capture(mixed, {"u": (12, 12), "out": (12, 12)})
    (st,) = prog.body
    a, b = st.rhs.kids
    assert (a.subs[0].a, a.subs[0].b) == (2, 1)
    assert (b.subs[0].a, b.subs[0].b) == (-1, 8)
    assert b.subs[1].a == 3
    # negative coefficients stay executable via the XLA gather path
    res = race(prog)
    env = {"u": np.random.default_rng(2).uniform(-1, 1, (12, 12))
           .astype(np.float32), "out": np.zeros((12, 12), np.float32)}
    got = np.asarray(res.run(env, "xla")["out"])
    want = np.zeros((4, 4), np.float32)
    for i in range(1, 5):
        for j in range(0, 4):
            want[i - 1, j] = env["u"][2 * i + 1, j] + env["u"][8 - i, 3 * j]
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_capture_augmented_assignment_desugars():
    def accum(u, out):
        n = len(u)
        for i in range(1, n - 1):
            out[i] += u[i + 1] * u[i - 1]

    prog = capture(accum, {"u": (9,), "out": (9,)})
    (st,) = prog.body
    assert isinstance(st.rhs, Node) and st.rhs.op == "+"
    assert st.rhs.kids[0] == st.lhs  # out[i] = out[i] + ...


def test_known_calls_mirror_codegen_funcs():
    assert set(KNOWN_CALLS) == set(FUNCS)


def test_run_with_consts_bound_parameter():
    def roll(u, out, n):
        for i in range(1, n):
            out[i] = u[i] + u[i - 1]

    kern = race_kernel(roll, reassociate=0)
    env = {"u": np.arange(8, dtype=np.float32),
           "out": np.zeros(8, np.float32)}
    got = kern.run(env, consts={"n": 8})  # n supplied as a const, not in env
    np.testing.assert_allclose(np.asarray(got["out"]),
                               env["u"][1:] + env["u"][:-1])


def test_numpy_attribute_calls_resolve_to_known_impls():
    def f(u, out):
        n = len(u)
        for i in range(1, n):
            out[i] = np.sqrt(u[i])

    prog = capture(f, {"u": (6,), "out": (6,)})
    assert prog.body[0].rhs.op == "call"
    assert prog.body[0].rhs.kids[0].name == "sqrt"


def test_numpy_scalars_are_capture_time_values():
    def scaled(u, out, n, w):
        for i in range(1, n):
            out[i] = w * u[i] + u[i - np.int64(1)]

    prog = capture(scaled, {"u": (8,), "out": (8,)},
                   consts={"n": np.int32(8), "w": np.float32(0.5)})
    assert prog.loops[0].hi == 7
    (st,) = prog.body
    coef, _ = st.rhs.kids[0].kids  # w * u[i] folded to Const(0.5)
    assert coef.val == 0.5
    assert st.rhs.kids[1].subs[0].b == -1


def test_captured_semantics_match_direct_python_execution():
    """The twin is executable Python: running it directly must agree with
    the captured program's baseline evaluator (source-vs-IR differential)."""
    case = get_case("poisson", 8)
    shapes = required_shapes(case.program)
    env = build_env(case, np.float64)
    direct = {k: np.array(v, np.float64) for k, v in env.items()}
    TWINS["poisson"](direct["u"], direct["fp"], direct["pois"],
                     float(direct["pc0"]), float(direct["pc1"]),
                     float(direct["pc2"]))
    prog = capture(TWINS["poisson"], shapes)
    got = race(prog).baseline_evaluator()(env)["pois"]
    # float32 JAX eval vs float64 Python loops
    np.testing.assert_allclose(np.asarray(got, np.float64), direct["pois"],
                               rtol=2e-5, atol=2e-6)
