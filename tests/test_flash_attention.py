"""Flash attention (chunked online-softmax, custom FA2-style VJP) vs a dense
reference: forward and gradients, across mask modes, chunk sizes and GQA
group counts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.models.common import ExecConfig


def dense_reference(q, k, v, causal, window):
    B, S, KV, G, dh = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


CASES = [
    # (S, T, KV, G, dh, causal, window, cq, ck)
    (16, 16, 2, 2, 8, True, 0, 4, 8),
    (16, 16, 1, 4, 8, False, 0, 8, 4),
    (24, 24, 2, 1, 16, True, 8, 8, 8),
    (32, 32, 1, 1, 8, True, 0, 32, 32),  # single chunk == dense
    (12, 12, 3, 2, 4, False, 5, 4, 6),
]


@pytest.mark.parametrize("S,T,KV,G,dh,causal,window,cq,ck", CASES)
def test_flash_forward_and_grads(S, T, KV, G, dh, causal, window, cq, ck):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, S, KV, G, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, T, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, T, KV, dh)), jnp.float32)
    ec = ExecConfig(attn_chunk_q=cq, attn_chunk_k=ck)

    out = flash_attention(q, k, v, causal, window, ec)
    ref = dense_reference(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, causal, window, ec)
                * jnp.arange(dh)).sum()

    def loss_ref(q, k, v):
        return (dense_reference(q, k, v, causal, window)
                * jnp.arange(dh)).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-4, err_msg=f"d{nm}")


def test_flash_probe_mode_equals_real_mode():
    """The dry-run probe configuration (unrolled, 2 chunks) must compute the
    same values as the production chunking."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
    real = flash_attention(q, k, v, True, 0,
                           ExecConfig(attn_chunk_q=4, attn_chunk_k=4))
    probe = flash_attention(q, k, v, True, 0,
                            ExecConfig(unroll_scans=True, probe_chunks=2))
    np.testing.assert_allclose(np.asarray(real), np.asarray(probe),
                               rtol=1e-5, atol=1e-6)
